"""Per-arch smoke + layer-level oracles (attention/MoE)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_NAMES, get_smoke_config
from repro.models import build_model
from repro.models.attention import (
    chunked_attention,
    decode_attention,
    full_attention,
)
from repro.models.blocks import layer_groups
from repro.models.common import init_params
from repro.models.moe import apply_moe, moe_defs, moe_dense_oracle
from repro.sharding.rules import smoke_topology


def _batch_for(cfg, B, S, key):
    if cfg.frontend == "vision":
        p = cfg.frontend_tokens
        return {"tokens": jax.random.randint(key, (B, S - p), 0,
                                             cfg.vocab_size),
                "embeds": jax.random.normal(key, (B, p, cfg.d_model),
                                            jnp.float32),
                "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_forward_and_decode(name):
    """Reduced config: one forward/loss + prefill + decode step on CPU;
    asserts output shapes and finiteness (the (f) deliverable)."""
    cfg = get_smoke_config(name)
    topo = smoke_topology(cfg)
    model = build_model(cfg, topo)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    loss, metrics = model.loss(params, batch)
    assert jnp.isfinite(loss), name
    assert float(metrics["ce"]) > 0

    cache, last = model.prefill(params, batch)
    assert last.shape == (B, 1, cfg.padded_vocab)
    logits, cache2 = model.decode_step(
        params, cache, jnp.zeros((B, 1), jnp.int32),
        jnp.full((B,), S, jnp.int32))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), name


@pytest.mark.parametrize("name", ["llama3-8b", "olmoe-1b-7b"])
def test_prefill_decode_matches_forward(name):
    """Greedy continuation: decode after prefill == forward on the longer
    sequence (cache correctness). capacity_factor is raised so MoE token
    drops can't differ between the two sequence lengths."""
    cfg = dataclasses.replace(get_smoke_config(name), capacity_factor=8.0)
    topo = smoke_topology(cfg)
    model = build_model(cfg, topo)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0,
                              cfg.vocab_size)
    full_logits, _, _ = model.forward(params, {"tokens": toks}, mode="full")
    cache, last = model.prefill(params, {"tokens": toks[:, :S]},
                                cache_len=S + 4)
    step_logits, _ = model.decode_step(params, cache, toks[:, S:S + 1],
                                       jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]), np.asarray(full_logits[:, S]),
        rtol=2e-2, atol=2e-2)


def test_chunked_attention_matches_full():
    k = jax.random.PRNGKey(0)
    B, S, H, KV, hd = 2, 128, 4, 2, 16
    q = jax.random.normal(k, (B, S, H, hd))
    kk = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd))
    for qc, kc in [(32, 32), (64, 128), (128, 32)]:
        a = full_attention(q, kk, v, causal=True)
        b = chunked_attention(q, kk, v, causal=True, q_chunk=qc, kv_chunk=kc)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_decode_attention_valid_len_masks_cache():
    from repro.models.attention import full_attention, write_kv_slot

    k = jax.random.PRNGKey(3)
    B, S, H, hd = 2, 16, 2, 8
    q = jax.random.normal(k, (B, 1, H, hd))
    kc = jax.random.normal(jax.random.PRNGKey(4), (B, S, H, hd))
    vc = jax.random.normal(jax.random.PRNGKey(5), (B, S, H, hd))
    kn = jax.random.normal(jax.random.PRNGKey(6), (B, 1, H, hd))
    vn = jax.random.normal(jax.random.PRNGKey(7), (B, 1, H, hd))
    # write at slot = valid_len = S-1 -> equals full attention over the
    # written cache
    vl = jnp.full((B,), S - 1, jnp.int32)
    kc2, vc2 = write_kv_slot(kc, vc, kn, vn, vl)
    o = decode_attention(q, kc2, vc2, vl, valid_len=vl)
    o_ref = full_attention(q, kc2, vc2, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-5)
    # valid_len = 0, slot 0: only the new token participates -> o == v_new
    z = jnp.zeros((B,), jnp.int32)
    kc3, vc3 = write_kv_slot(kc, vc, kn, vn, z)
    o0 = decode_attention(q, kc3, vc3, z, valid_len=z)
    np.testing.assert_allclose(np.asarray(o0[:, 0]), np.asarray(vn[:, 0]),
                               atol=1e-5)


def test_moe_sorted_dispatch_matches_oracle(rng):
    cfg = dataclasses.replace(get_smoke_config("olmoe-1b-7b"),
                              capacity_factor=8.0, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), moe_defs(cfg), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    topo = smoke_topology(cfg)
    y, aux = apply_moe(params, x, cfg, topo)
    y_ref, aux_ref = moe_dense_oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
    assert np.isclose(float(aux), float(aux_ref))
    assert 0.9 < float(aux) < 4.0  # balanced-ish at init; E[aux] ~ 1


def test_moe_capacity_drops_pass_residual():
    cfg = dataclasses.replace(get_smoke_config("olmoe-1b-7b"),
                              capacity_factor=0.1, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), moe_defs(cfg), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    topo = smoke_topology(cfg)
    y, _ = apply_moe(params, x, cfg, topo)
    assert bool(jnp.isfinite(y).all())
    # with tiny capacity most tokens are dropped -> output mostly zero
    frac_zero = float((jnp.abs(y) < 1e-9).mean())
    assert frac_zero > 0.3


def test_layer_groups_decomposition():
    from repro.configs.registry import get_config

    for name, want in [("llama3-8b", (0, 1, 32)),
                       ("deepseek-moe-16b", (1, 1, 27))]:
        specs = get_config(name).layer_specs()
        g = layer_groups(specs)
        got = (len(g.prefix), len(g.pattern), g.n_repeat)
        assert got == want, (name, got, want)
        # reconstruction
        flat = list(g.prefix) + list(g.pattern) * g.n_repeat
        assert tuple(flat) == specs
