"""End-to-end behaviour tests for the paper's system.

The full pipeline: CWC model -> compile -> mesh-farm ensemble ->
time-sliced windows -> on-line reduction -> statistics stream, plus the
scheduler/stream/straggler substrate units.
"""
import numpy as np
import pytest

from repro.core.cwc.models import ecoli_gene_regulation, lotka_volterra
from repro.core.engine import SimConfig, SimulationEngine
from repro.core.scheduler import Scheduler
from repro.core.stream import StatsStream, StatsRecord, csv_sink
from repro.runtime.straggler import WindowWatchdog


def test_end_to_end_fig1_style():
    """The paper's Fig. 1 experiment shape: N independent instances of
    the E. coli regulation model, mean + 90% CI on a fixed grid."""
    cfg = SimConfig(n_instances=100, t_end=20.0, n_windows=10, n_lanes=100,
                    schema="iii", seed=0)
    eng = SimulationEngine(ecoli_gene_regulation(), cfg)
    recs = eng.run()
    assert len(recs) == 10
    assert all(r.n == 100 for r in recs)
    protein = np.array([r.mean[1] for r in recs])
    # protein rises from 0 and the CI is meaningful
    assert protein[0] < protein[-1]
    assert all(r.ci90[1] > 0 for r in recs[1:])
    # stream got every record
    assert len(eng.stream.records()) == 10


def test_scheduler_groups_cover_everything():
    s = Scheduler(n_instances=37, n_lanes=8, policy="on_demand")
    gs = s.groups()
    seen = set()
    for g in gs:
        assert len(g) == 8
        seen.update(g.tolist())
    assert seen == set(range(37))


def test_scheduler_predictive_sorts_by_cost():
    s = Scheduler(n_instances=16, n_lanes=4, policy="predictive")
    costs = np.arange(16)[::-1].astype(float)  # instance 0 most expensive
    s.record_costs(np.arange(16), costs)
    gs = s.groups()
    # cheapest instances grouped together first
    assert set(gs[0].tolist()) == {15, 14, 13, 12}
    assert set(gs[-1].tolist()) == {3, 2, 1, 0}
    assert s.imbalance() > 0.5


def test_stats_stream_and_csv(tmp_path):
    stream = StatsStream(maxlen=4)
    path = str(tmp_path / "out.csv")
    stream.attach(csv_sink(path, ["a", "b"]))
    for w in range(6):
        stream.emit(StatsRecord(
            t=float(w), window=w, mean=np.array([w, 2 * w], float),
            var=np.zeros(2), ci90=np.zeros(2), n=10))
    assert stream.dropped == 2  # bounded buffer
    stream.close()  # sinks flush-on-close (no per-row flush)
    lines = open(path).read().strip().splitlines()
    assert len(lines) == 7  # header + all 6 (sink sees everything)
    assert lines[0].startswith("t,n,a_mean")


def test_watchdog_flags_stragglers():
    w = WindowWatchdog(factor=3.0)
    for _ in range(10):
        assert not w.observe(0, 1.0)
    assert w.observe(10, 10.0)
    assert w.straggler_rate() > 0


def test_sweep_end_to_end_separates_points():
    from repro.core.cwc.compile import compile_model
    from repro.core.sweep import SweepSpec, sweep_rates

    model = lotka_volterra(2)
    system, _ = compile_model(model)
    # 64 replicas per point: prey is near extinction by t_end, so the
    # 16-replica original separated only by seed luck (too tight for a
    # one-sided mean comparison)
    spec = SweepSpec.make({"reproduce": [0.5, 2.0]}, replicas=64)
    cfg = SimConfig(n_instances=spec.n_instances(), t_end=1.5, n_windows=3,
                    n_lanes=32, schema="iii", seed=4)
    eng = SimulationEngine(model, cfg, rates=sweep_rates(system, spec))
    eng.run()
    x = np.asarray(eng._pool.x)
    prey_low, prey_high = x[:64, 0].mean(), x[64:, 0].mean()
    assert prey_high > prey_low  # higher birth rate -> more prey
